//! Bitcask-style persistent KV store (the SQLite-substitute DM-Shard
//! backend).
//!
//! On-disk format — a single append-only log of records:
//!
//! ```text
//! record  := crc32(u32 LE over payload) payload
//! payload := kind(u8: 1=put 2=del) klen(u32 LE) vlen(u32 LE) key value
//! ```
//!
//! The in-memory index maps live keys to (offset, vlen) of their latest
//! record; values are read back from the file (a small value cache is a
//! perf knob left to the OS page cache). Recovery scans the log and stops
//! at the first corrupt/truncated record, truncating the tail — a torn
//! final write is thereby dropped, which is exactly the crash semantics
//! the paper's tagged-consistency design assumes (the lost CIT flag flip
//! re-marks the chunk invalid).

use super::KvStore;
use crate::error::{Error, Result};
use crate::util::codec::crc32;
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

const KIND_PUT: u8 = 1;
const KIND_DEL: u8 = 2;
const HEADER: usize = 4 + 1 + 4 + 4; // crc + kind + klen + vlen

struct Inner {
    file: File,
    // key -> (value offset, vlen); ordered so prefix range reads (the
    // backreference index's access pattern) avoid full-index filters
    index: BTreeMap<Vec<u8>, (u64, u32)>,
    tail: u64,       // append position
    dead_bytes: u64, // garbage from overwrites/deletes
}

/// Persistent append-only KV store with crash recovery and compaction.
pub struct LogKv {
    path: PathBuf,
    inner: Mutex<Inner>,
}

impl LogKv {
    /// Open (or create) the log at `path`, replaying it to rebuild the
    /// index. A torn tail (bad CRC / truncated record) is truncated.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)?;
        let mut data = Vec::new();
        file.read_to_end(&mut data)?;

        let mut index = BTreeMap::new();
        let mut dead_bytes = 0u64;
        let mut pos = 0usize;
        let valid_end = loop {
            if pos + HEADER > data.len() {
                break pos;
            }
            let crc = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap());
            let kind = data[pos + 4];
            let klen = u32::from_le_bytes(data[pos + 5..pos + 9].try_into().unwrap()) as usize;
            let vlen = u32::from_le_bytes(data[pos + 9..pos + 13].try_into().unwrap()) as usize;
            let body_end = pos + HEADER + klen + vlen;
            if body_end > data.len() || (kind != KIND_PUT && kind != KIND_DEL) {
                break pos;
            }
            if crc32(&data[pos + 4..body_end]) != crc {
                break pos;
            }
            let key = data[pos + HEADER..pos + HEADER + klen].to_vec();
            match kind {
                KIND_PUT => {
                    let voff = (pos + HEADER + klen) as u64;
                    if let Some((_, old_vlen)) = index.insert(key, (voff, vlen as u32)) {
                        dead_bytes += HEADER as u64 + old_vlen as u64;
                    }
                }
                _ => {
                    if let Some((_, old_vlen)) = index.remove(&key) {
                        dead_bytes += 2 * HEADER as u64 + old_vlen as u64 + klen as u64;
                    }
                }
            }
            pos = body_end;
        };
        if valid_end < data.len() {
            // torn tail: drop it.
            file.set_len(valid_end as u64)?;
        }
        file.seek(SeekFrom::Start(valid_end as u64))?;
        Ok(LogKv {
            path,
            inner: Mutex::new(Inner {
                file,
                index,
                tail: valid_end as u64,
                dead_bytes,
            }),
        })
    }

    fn append(inner: &mut Inner, kind: u8, key: &[u8], value: &[u8]) -> Result<u64> {
        let mut payload = Vec::with_capacity(HEADER - 4 + key.len() + value.len());
        payload.push(kind);
        payload.extend_from_slice(&(key.len() as u32).to_le_bytes());
        payload.extend_from_slice(&(value.len() as u32).to_le_bytes());
        payload.extend_from_slice(key);
        payload.extend_from_slice(value);
        let crc = crc32(&payload);
        inner.file.seek(SeekFrom::Start(inner.tail))?;
        inner.file.write_all(&crc.to_le_bytes())?;
        inner.file.write_all(&payload)?;
        let rec_start = inner.tail;
        inner.tail += 4 + payload.len() as u64;
        Ok(rec_start)
    }

    /// Bytes of garbage (overwritten/deleted records) currently in the log.
    pub fn dead_bytes(&self) -> u64 {
        self.inner.lock().unwrap().dead_bytes
    }

    /// Rewrite the log keeping only live records. Returns bytes reclaimed.
    pub fn compact(&self) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let tmp_path = self.path.with_extension("compact");
        let mut tmp = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp_path)?;
        // copy live records
        let keys: Vec<Vec<u8>> = inner.index.keys().cloned().collect();
        let mut new_index = BTreeMap::new();
        let mut new_tail = 0u64;
        for key in keys {
            let (voff, vlen) = inner.index[&key];
            let mut value = vec![0u8; vlen as usize];
            inner.file.seek(SeekFrom::Start(voff))?;
            inner.file.read_exact(&mut value)?;
            let mut payload = Vec::with_capacity(HEADER - 4 + key.len() + value.len());
            payload.push(KIND_PUT);
            payload.extend_from_slice(&(key.len() as u32).to_le_bytes());
            payload.extend_from_slice(&(value.len() as u32).to_le_bytes());
            payload.extend_from_slice(&key);
            payload.extend_from_slice(&value);
            let crc = crc32(&payload);
            tmp.write_all(&crc.to_le_bytes())?;
            tmp.write_all(&payload)?;
            let voff_new = new_tail + HEADER as u64 + key.len() as u64;
            new_index.insert(key, (voff_new, vlen));
            new_tail += 4 + payload.len() as u64;
        }
        tmp.sync_all()?;
        let reclaimed = inner.tail.saturating_sub(new_tail);
        std::fs::rename(&tmp_path, &self.path)?;
        inner.file = OpenOptions::new().read(true).write(true).open(&self.path)?;
        inner.file.seek(SeekFrom::Start(new_tail))?;
        inner.index = new_index;
        inner.tail = new_tail;
        inner.dead_bytes = 0;
        Ok(reclaimed)
    }
}

impl KvStore for LogKv {
    fn put(&self, key: &[u8], value: &[u8]) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let rec_start = Self::append(&mut inner, KIND_PUT, key, value)?;
        let voff = rec_start + HEADER as u64 + key.len() as u64;
        if let Some((_, old_vlen)) = inner.index.insert(key.to_vec(), (voff, value.len() as u32)) {
            inner.dead_bytes += HEADER as u64 + old_vlen as u64 + key.len() as u64;
        }
        Ok(())
    }

    fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        let Some(&(voff, vlen)) = inner.index.get(key) else {
            return Ok(None);
        };
        let mut value = vec![0u8; vlen as usize];
        inner.file.seek(SeekFrom::Start(voff))?;
        inner.file.read_exact(&mut value)?;
        // restore append position for the next write
        let tail = inner.tail;
        inner.file.seek(SeekFrom::Start(tail))?;
        Ok(Some(value))
    }

    fn delete(&self, key: &[u8]) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.index.contains_key(key) {
            return Ok(false);
        }
        Self::append(&mut inner, KIND_DEL, key, b"")?;
        if let Some((_, old_vlen)) = inner.index.remove(key) {
            inner.dead_bytes += 2 * (HEADER as u64 + key.len() as u64) + old_vlen as u64;
        }
        Ok(true)
    }

    fn keys(&self) -> Result<Vec<Vec<u8>>> {
        Ok(self.inner.lock().unwrap().index.keys().cloned().collect())
    }

    fn scan_prefix(&self, prefix: &[u8]) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        let mut inner = self.inner.lock().unwrap();
        // ordered range over the BTree index, then one value read each
        let locations: Vec<(Vec<u8>, u64, u32)> = inner
            .index
            .range(prefix.to_vec()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, &(voff, vlen))| (k.clone(), voff, vlen))
            .collect();
        let mut out = Vec::with_capacity(locations.len());
        for (key, voff, vlen) in locations {
            let mut value = vec![0u8; vlen as usize];
            inner.file.seek(SeekFrom::Start(voff))?;
            inner.file.read_exact(&mut value)?;
            out.push((key, value));
        }
        // restore append position for the next write
        let tail = inner.tail;
        inner.file.seek(SeekFrom::Start(tail))?;
        Ok(out)
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().index.len()
    }

    fn sync(&self) -> Result<()> {
        self.inner
            .lock()
            .unwrap()
            .file
            .sync_all()
            .map_err(Error::Io)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::conformance;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("snss-logkv-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn conformance_basic() {
        let d = tmpdir("basic");
        conformance::basic_ops(&LogKv::open(d.join("kv.log")).unwrap());
    }

    #[test]
    fn conformance_binary() {
        let d = tmpdir("binary");
        conformance::binary_safety(&LogKv::open(d.join("kv.log")).unwrap());
    }

    #[test]
    fn conformance_scan_prefix() {
        let d = tmpdir("scan");
        conformance::prefix_scan(&LogKv::open(d.join("kv.log")).unwrap());
    }

    #[test]
    fn reopen_recovers_state() {
        let d = tmpdir("reopen");
        let path = d.join("kv.log");
        {
            let kv = LogKv::open(&path).unwrap();
            kv.put(b"a", b"1").unwrap();
            kv.put(b"b", b"2").unwrap();
            kv.put(b"a", b"3").unwrap();
            kv.delete(b"b").unwrap();
            kv.sync().unwrap();
        }
        let kv = LogKv::open(&path).unwrap();
        assert_eq!(kv.get(b"a").unwrap().unwrap(), b"3");
        assert_eq!(kv.get(b"b").unwrap(), None);
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn torn_tail_truncated() {
        let d = tmpdir("torn");
        let path = d.join("kv.log");
        {
            let kv = LogKv::open(&path).unwrap();
            kv.put(b"a", b"1").unwrap();
            kv.put(b"b", b"2").unwrap();
            kv.sync().unwrap();
        }
        // corrupt: chop 3 bytes off the tail (torn final record)
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        let kv = LogKv::open(&path).unwrap();
        assert_eq!(kv.get(b"a").unwrap().unwrap(), b"1");
        assert_eq!(kv.get(b"b").unwrap(), None, "torn record dropped");
    }

    #[test]
    fn corrupt_crc_stops_replay() {
        let d = tmpdir("crc");
        let path = d.join("kv.log");
        let second_rec_at;
        {
            let kv = LogKv::open(&path).unwrap();
            kv.put(b"a", b"1").unwrap();
            second_rec_at = std::fs::metadata(&path).unwrap().len();
            kv.put(b"b", b"2").unwrap();
            kv.put(b"c", b"3").unwrap();
            kv.sync().unwrap();
        }
        // flip a byte inside the second record's value
        let mut data = std::fs::read(&path).unwrap();
        data[second_rec_at as usize + HEADER + 1] ^= 0xFF;
        std::fs::write(&path, &data).unwrap();
        let kv = LogKv::open(&path).unwrap();
        assert_eq!(kv.get(b"a").unwrap().unwrap(), b"1");
        assert_eq!(kv.get(b"b").unwrap(), None);
        assert_eq!(kv.get(b"c").unwrap(), None, "everything after corruption dropped");
    }

    #[test]
    fn compaction_reclaims_and_preserves() {
        let d = tmpdir("compact");
        let path = d.join("kv.log");
        let kv = LogKv::open(&path).unwrap();
        for i in 0..50u32 {
            kv.put(b"hot", format!("version-{i}").as_bytes()).unwrap();
        }
        kv.put(b"cold", b"keep-me").unwrap();
        kv.delete(b"hot").unwrap();
        assert!(kv.dead_bytes() > 0);
        let reclaimed = kv.compact().unwrap();
        assert!(reclaimed > 0);
        assert_eq!(kv.dead_bytes(), 0);
        assert_eq!(kv.get(b"cold").unwrap().unwrap(), b"keep-me");
        assert_eq!(kv.get(b"hot").unwrap(), None);
        // and still durable across reopen
        drop(kv);
        let kv = LogKv::open(&path).unwrap();
        assert_eq!(kv.get(b"cold").unwrap().unwrap(), b"keep-me");
    }

    #[test]
    fn property_model_check_vs_btreemap() {
        use crate::util::prop;
        use std::collections::BTreeMap;
        let d = tmpdir("model");
        let mut case = 0u32;
        prop::check(
            prop::Config { cases: 24, ..Default::default() },
            |rng, size| {
                // a script of (op, key, value) steps
                let steps = 5 + (size as usize) / 2;
                (0..steps)
                    .map(|_| {
                        let op = rng.below(3) as u8;
                        let key = prop::ident(rng, 4);
                        let val = prop::bytes(rng, 32);
                        (op, key, val)
                    })
                    .collect::<Vec<_>>()
            },
            |script| {
                case += 1;
                let path = d.join(format!("model-{case}.log"));
                let kv = LogKv::open(&path).unwrap();
                let mut model: BTreeMap<Vec<u8>, Vec<u8>> = BTreeMap::new();
                for (op, key, val) in script {
                    let k = key.as_bytes();
                    match op {
                        0 => {
                            kv.put(k, val).unwrap();
                            model.insert(k.to_vec(), val.clone());
                        }
                        1 => {
                            let got = kv.delete(k).unwrap();
                            let exp = model.remove(k).is_some();
                            if got != exp {
                                return Err(format!("delete({key}) {got} != {exp}"));
                            }
                        }
                        _ => {
                            let got = kv.get(k).unwrap();
                            let exp = model.get(k).cloned();
                            if got != exp {
                                return Err(format!("get({key}) mismatch"));
                            }
                        }
                    }
                }
                // reopen and compare the full map
                drop(kv);
                let kv = LogKv::open(&path).unwrap();
                if kv.len() != model.len() {
                    return Err(format!("reopen len {} != {}", kv.len(), model.len()));
                }
                for (k, v) in &model {
                    if kv.get(k).unwrap().as_deref() != Some(v.as_slice()) {
                        return Err("reopen value mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}
