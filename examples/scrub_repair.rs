//! Online scrub & repair: silent data corruption is found and healed
//! while the cluster keeps serving writes.
//!
//! 1. **Bit-rot on a primary chunk** — a deep scrub re-reads every chunk,
//!    re-fingerprints it through the batched SHA-1 provider, catches the
//!    flipped bit and restores the chunk from a digest-verified replica.
//! 2. **A lost replica copy** — the primary's scrub compares its copies
//!    over the wire (only digest verdicts cross, never data) and
//!    re-pushes the missing one.
//! 3. **Crash mid-repair** — the scrubbing server dies between detection
//!    and repair; after a restart, the next pass converges to a clean
//!    audit (the paper's robustness claim, extended to the scrubber
//!    itself).
//!
//! Scrubbing is rate-limited by a token bucket and runs concurrently
//! with foreground I/O — no cluster-wide quiesce.
//!
//! ```text
//! cargo run --release --example scrub_repair
//! ```

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, ScrubOptions};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::failure::CrashPoint;
use snss_dedup::workload::{Generator, WorkloadSpec};

fn corrupt_first_chunk(cluster: &Cluster, id: ServerId) -> bool {
    cluster
        .with_osd(id, |sh| -> snss_dedup::Result<bool> {
            for key in sh.store.keys()? {
                if key.len() != 20 {
                    continue;
                }
                if let Some(mut data) = sh.store.get(&key)? {
                    if !data.is_empty() {
                        data[0] ^= 0x01;
                        sh.store.put(&key, &data)?;
                        return Ok(true);
                    }
                }
            }
            Ok(false)
        })
        .expect("server alive")
        .expect("store io")
}

fn main() {
    println!("== scrub_repair: online integrity verification & healing ==");
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .expect("boot");
    let client = cluster.client();

    // a corpus of 12 objects, 25% duplicate blocks
    let gen = Generator::new(WorkloadSpec {
        object_size: 128 << 10,
        unit: 4096,
        dedup_pct: 25,
        ..Default::default()
    });
    for i in 0..12 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).expect("put");
    }
    cluster.flush_consistency().ok();

    // -- inject two silent faults ------------------------------------
    assert!(corrupt_first_chunk(&cluster, ServerId(0)));
    println!("injected: bit-flip in a primary chunk on osd.0");
    let dropped = cluster
        .with_osd(ServerId(1), |sh| -> snss_dedup::Result<bool> {
            for key in sh.replica_store.keys()? {
                if key.starts_with(b"c:") && key.len() == 22 {
                    sh.replica_store.delete(&key)?;
                    return Ok(true);
                }
            }
            Ok(false)
        })
        .expect("server alive")
        .expect("replica io");
    println!("injected: dropped replica copy on osd.1 = {dropped}");

    // -- deep scrub under live foreground writes ---------------------
    let writer = {
        let client = cluster.client();
        std::thread::spawn(move || {
            for i in 0..16u32 {
                let data: Vec<u8> = (0..65_536u32).map(|j| (j * 131 + i) as u8).collect();
                client.put_object(&format!("live-{i}"), &data).expect("live put");
            }
        })
    };
    cluster
        .start_scrub(ScrubOptions::deep().with_rate(8 << 20).with_window(64))
        .expect("start scrub");
    let report = cluster.scrub_wait().expect("scrub");
    writer.join().expect("writer");
    println!(
        "deep scrub: checked {} chunks / {} KiB, corruptions {}, repaired {}, refs fixed {}",
        report.chunks_checked,
        report.bytes_verified >> 10,
        report.corruptions_found,
        report.repaired,
        report.refs_fixed,
    );
    assert!(report.corruptions_found >= 1, "bit-flip must be caught");
    assert!(report.repaired >= 1, "faults must be healed");

    // settle in-flight writes, reconcile, verify
    cluster.flush_consistency().ok();
    cluster.scrub().expect("light scrub");
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{:?}", audit.violations);
    for i in 0..12 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).expect("read"), data, "{name}");
    }
    println!("audit clean; all objects byte-identical after healing");

    // -- crash in the middle of a repair -----------------------------
    assert!(corrupt_first_chunk(&cluster, ServerId(2)));
    cluster
        .arm_crash(ServerId(2), CrashPoint::BeforeScrubRepair)
        .expect("arm");
    cluster.start_scrub(ScrubOptions::deep()).expect("start");
    let _ = cluster.scrub_wait().expect("wait (dead server skipped)");
    println!(
        "osd.2 crashed mid-repair (dead={}), corruption still on disk",
        cluster.is_dead(ServerId(2))
    );
    cluster.restart_server(ServerId(2)).expect("restart");
    cluster.flush_consistency().ok();
    cluster.start_scrub(ScrubOptions::deep()).expect("rescrub");
    let report = cluster.scrub_wait().expect("scrub");
    println!(
        "re-scrub after restart: corruptions {}, repaired {}",
        report.corruptions_found, report.repaired
    );
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{:?}", audit.violations);

    let stats = cluster.stats();
    println!(
        "final: scrub_chunks_checked={} scrub_bytes_verified={} \
         scrub_corruptions_found={} scrub_repaired={} repairs={} savings={:.1}%",
        stats.scrub_chunks_checked,
        stats.scrub_bytes_verified,
        stats.scrub_corruptions_found,
        stats.scrub_repaired,
        stats.repairs,
        stats.savings() * 100.0
    );
    cluster.shutdown();
    println!("scrub_repair OK");
}
