//! Robustness under sudden server failure (paper §2.4 + abstract claim):
//!
//! 1. **Crash mid-transaction** — a chunk server dies right after storing
//!    chunk data but before its commit flag flips. The write transaction
//!    aborts and rolls back; the orphan chunk sits quarantined behind its
//!    invalid flag.
//! 2. **Restart + recovery scan** — the revived server re-registers
//!    stored-but-invalid chunks; the consistency manager re-validates them.
//! 3. **Duplicate-write repair** — a later duplicate write that hits an
//!    invalid entry stats the chunk and repairs in-line (the paper's
//!    consistency check).
//! 4. **GC** — garbage of genuinely failed transactions (refcount 0,
//!    invalid flag, past threshold) is reclaimed; nothing live is touched.
//! 5. **Degraded reads** — with a killed chunk server, reads fall back to
//!    replica copies.
//!
//! ```text
//! cargo run --release --example failure_recovery
//! ```

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::failure::CrashPoint;

fn main() {
    println!("== failure_recovery: crash-mid-transaction + repair + GC ==");
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        ..Default::default()
    })
    .expect("boot");
    let client = cluster.client();

    // healthy baseline
    let stable = vec![3u8; 64 << 10];
    client.put_object("stable", &stable).expect("put stable");
    cluster.flush_consistency().ok();

    // 1. arm a crash on one victim server at the after-data-store point:
    // with 32 unique chunks spread content-wise over 4 servers, the doomed
    // object is certain to route at least one chunk to osd.1, which then
    // dies with a stored-but-invalid chunk.
    cluster
        .arm_crash(ServerId(1), CrashPoint::AfterDataStore)
        .expect("arm");
    let doomed: Vec<u8> = (0..128u32 << 10).map(|i| (i * 2654435761 >> 7) as u8).collect();
    let crashed = match client.put_object("doomed", &doomed) {
        Err(e) => {
            println!("write failed as expected: {e}");
            true
        }
        Ok(_) => {
            println!("write survived (crash hit a non-critical server)");
            false
        }
    };

    // find the dead server(s)
    let mut dead = Vec::new();
    for i in 0..4 {
        let id = ServerId(i);
        if cluster.is_dead(id) {
            dead.push(id);
        }
    }
    println!("dead servers: {dead:?} (crashed={crashed})");

    // 5. degraded reads: 'stable' must still be fully readable even with
    // a server down, via replica copies.
    assert_eq!(client.get_object("stable").expect("degraded read"), stable);
    println!("degraded read of 'stable' OK with {} server(s) dead", dead.len());

    // 2. restart the dead servers: recovery scan re-registers
    // stored-but-invalid chunks and the flag manager re-validates them.
    for id in &dead {
        cluster.restart_server(*id).expect("restart");
    }
    cluster.flush_consistency().ok();

    // 3. rewrite the doomed object: duplicate writes over invalid entries
    // take the repair path (stat + flip + refcount grant).
    client.put_object("doomed", &doomed).expect("rewrite after restart");
    assert_eq!(client.get_object("doomed").expect("read doomed"), doomed);
    println!("rewrite + readback after restart OK");

    // 4a. scrub: the failed transaction's rollback could not reach the
    // crashed server, so one chunk's refcount leaked high; the cross-match
    // scrub recomputes refcounts from cluster-wide OMAP references.
    let repaired = cluster.scrub().expect("scrub");
    println!("scrub repaired {repaired} leaked refcount(s)");

    // 4b. GC pass with zero threshold: failed-transaction leftovers
    // (refcount 0 + invalid) are reclaimed; everything referenced stays.
    cluster.flush_consistency().ok();
    cluster.run_gc(0).expect("gc");
    assert_eq!(client.get_object("stable").expect("stable after gc"), stable);
    assert_eq!(client.get_object("doomed").expect("doomed after gc"), doomed);

    let audit = cluster.audit().expect("audit");
    let stats = cluster.stats();
    println!(
        "final: repairs={} gc_reclaimed={} tx_aborts={} audit={}",
        stats.repairs,
        stats.gc_reclaimed,
        stats.tx_aborts,
        if audit.is_ok() { "OK" } else { "VIOLATIONS" }
    );
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
    println!("failure_recovery OK");
}
