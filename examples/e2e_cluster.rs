//! End-to-end driver — the full stack on a real workload.
//!
//! Loads a real corpus (this repository's own Rust + Python sources),
//! writes it to the cluster **three times** under distinct snapshot names
//! (a classic backup workload — the second and third generations are pure
//! duplicates), through the **XLA/Pallas fingerprint engine** when
//! artifacts are available. Reports the paper's headline metrics —
//! cluster-wide space savings, write bandwidth, per-server balance — then
//! kills a server and proves every file is still readable (degraded
//! reads), and finally audits the refcount invariant cluster-wide.
//!
//! Recorded in EXPERIMENTS.md §E2E.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_cluster
//! ```

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, FingerprintBackend};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::corpus;
use std::time::Instant;

fn main() {
    println!("== e2e_cluster: real corpus, 3 backup generations, 6 servers ==");
    let fingerprint = if std::path::Path::new("artifacts/manifest.tsv").exists() {
        println!("fingerprint engine: XLA (AOT Pallas SHA-1 kernel)");
        FingerprintBackend::Xla {
            artifacts_dir: "artifacts".into(),
        }
    } else {
        println!("fingerprint engine: scalar Rust SHA-1 (run `make artifacts` for XLA)");
        FingerprintBackend::RustSha1
    };

    let cluster = Cluster::new(ClusterConfig {
        servers: 6,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        fingerprint,
        ..Default::default()
    })
    .expect("boot");
    let client = cluster.client();

    // real corpus: this repo's sources
    let mut objects = corpus::load_dir("rust/src", 1 << 20).expect("load corpus");
    objects.extend(corpus::load_dir("python", 1 << 20).expect("load python corpus"));
    let corpus_bytes: u64 = objects.iter().map(|o| o.data.len() as u64).sum();
    println!(
        "corpus: {} files, {:.2} MiB",
        objects.len(),
        corpus_bytes as f64 / (1 << 20) as f64
    );
    assert!(objects.len() > 20, "corpus too small");

    // three backup generations
    let t0 = Instant::now();
    for generation in 0..3 {
        for obj in &objects {
            let name = format!("backup{generation}/{}", obj.name);
            client.put_object(&name, &obj.data).expect("put");
        }
    }
    let dt = t0.elapsed();
    cluster.flush_consistency().ok();

    let stats = cluster.stats();
    let logical_mib = stats.logical_bytes as f64 / (1 << 20) as f64;
    println!(
        "wrote {logical_mib:.2} MiB logical in {:.2}s -> {:.1} MiB/s",
        dt.as_secs_f64(),
        logical_mib / dt.as_secs_f64()
    );
    println!(
        "stored {:.2} MiB unique -> savings {:.1}% (3 generations => >= 66.7% floor)",
        stats.stored_bytes as f64 / (1 << 20) as f64,
        stats.savings() * 100.0
    );
    let per: Vec<u64> = stats.per_server.iter().map(|s| s.bytes_stored >> 10).collect();
    println!("per-server KiB: {per:?}");
    assert!(
        stats.savings() > 0.60,
        "three identical generations must dedup: {}",
        stats.savings()
    );

    // spot-verify readback
    for obj in objects.iter().take(25) {
        let back = client.get_object(&format!("backup1/{}", obj.name)).expect("get");
        assert_eq!(back, obj.data, "{}", obj.name);
    }
    println!("readback spot-check (25 files) OK");

    // kill a server; every generation-2 file must still be readable
    cluster.kill_server(ServerId(2)).expect("kill");
    let mut degraded_ok = 0usize;
    for obj in objects.iter() {
        let back = client
            .get_object(&format!("backup2/{}", obj.name))
            .expect("degraded get");
        assert_eq!(back, obj.data, "{}", obj.name);
        degraded_ok += 1;
    }
    println!("degraded reads with osd.2 dead: {degraded_ok}/{} files OK", objects.len());
    cluster.restart_server(ServerId(2)).expect("restart");
    cluster.flush_consistency().ok();

    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "audit violations: {:?}", audit.violations);
    println!(
        "audit: {} fingerprints, {} references, OK",
        audit.fingerprints, audit.references
    );
    cluster.shutdown();
    println!("e2e_cluster OK");
}
