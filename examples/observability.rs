//! Observability end to end: distributed tracing, the per-server
//! metrics registry and both expositions on a small cluster that loses
//! and recovers a server mid-run.
//!
//! 1. **Traced workload** — puts and gets with the tail threshold at
//!    zero, so every op's span tree is retained.
//! 2. **Kill/recover cycle** — one server dies (its span ring dies with
//!    it), reads degrade to replica copies, the server restarts.
//! 3. **Exposition** — the Prometheus-style text rendering of a full
//!    cluster snapshot, the derived skew / read-amplification signals,
//!    and the reassembled span tree of the slowest client op.
//!
//! ```text
//! cargo run --release --example observability
//! ```

use snss_dedup::api::{Cluster, ClusterConfig};
use snss_dedup::cluster::ServerId;
use snss_dedup::dedup::Chunking;
use snss_dedup::obs::ObsConfig;

fn main() {
    println!("== observability: tracing + per-server metrics + exposition ==");
    let cluster = Cluster::new(ClusterConfig {
        servers: 3,
        replication: 2,
        chunking: Chunking::Fixed { size: 4096 },
        obs: ObsConfig {
            // retain every op's span tree (production would keep the
            // default slow-op threshold and a 1-in-N exemplar stream)
            slow_op_threshold_ms: 0,
            span_ring_capacity: 4096,
            retained_traces: 128,
            ..ObsConfig::default()
        },
        ..Default::default()
    })
    .expect("boot");
    let client = cluster.client();

    // 1. traced workload: every put/get opens a client root span whose
    // context rides in each fabric envelope it causes
    let mut objects = Vec::new();
    for i in 0..8u32 {
        let data: Vec<u8> = (0..32u32 << 10)
            .map(|j| ((j * 2654435761).rotate_left(i) >> 9) as u8)
            .collect();
        client.put_object(&format!("obj-{i}"), &data).expect("put");
        objects.push(data);
    }
    for (i, data) in objects.iter().enumerate() {
        assert_eq!(&client.get_object(&format!("obj-{i}")).expect("get"), data);
    }

    // 2. kill/recover cycle: the dead server's span ring is volatile
    // and cleared (crash semantics); reads fall back to replica copies
    cluster.kill_server(ServerId(1)).expect("kill");
    assert_eq!(
        &client.get_object("obj-0").expect("degraded read"),
        &objects[0]
    );
    println!("degraded read OK with osd.1 dead");
    cluster.restart_server(ServerId(1)).expect("restart");
    cluster.flush_consistency().ok();

    // 3a. the full Prometheus-style text exposition
    let snap = cluster.metrics_snapshot();
    println!("\n---- metrics_snapshot().to_prometheus() ----");
    print!("{}", snap.to_prometheus());

    // 3b. derived signals the per-server registry makes possible
    let reads = snap.counter_total("read_amp_reads");
    let homes = snap.counter_total("read_amp_homes");
    println!("\n---- derived signals ----");
    println!(
        "read amplification: {homes} chunk-home hits / {reads} reads = {:.2} servers per read",
        homes as f64 / reads.max(1) as f64
    );
    println!("unique_chunks skew (max/mean): {:.2}", snap.skew("unique_chunks"));
    println!(
        "hot servers (>1.5x mean unique_chunks): {:?}",
        snap.hot_servers("unique_chunks", 1.5)
    );
    let put = snap.histogram_total("put_latency");
    println!(
        "cluster put latency: count={} p50={}us p99={}us",
        put.count,
        put.p50_us(),
        put.p99_us()
    );

    // 3c. the slowest client op's reassembled cross-server span tree
    let dump = cluster.trace_dump();
    let slowest = dump
        .traces
        .iter()
        .max_by_key(|t| t.root().map(|r| r.duration_ms()).unwrap_or(0))
        .expect("at least one retained trace");
    println!("\n---- slowest op's span tree ----");
    print!("{}", slowest.render());

    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
    println!("observability OK");
}
