//! Storage rebalancing under content-based placement (paper §2.3,
//! Figure 1(b)): add a server to a loaded cluster, rebalance, and verify
//! that (a) every object remains readable, (b) dedup metadata needed no
//! cluster-wide refresh (the audit still balances), and (c) the movement
//! volume is close to the straw2 ideal 1/(n+1). Also contrasts the straw2
//! and rendezvous placement policies (the DESIGN.md ablation).
//!
//! ```text
//! cargo run --release --example rebalancing
//! ```

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, Placement};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};

fn run(policy: Placement, label: &str) {
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 8192 },
        placement: policy,
        ..Default::default()
    })
    .expect("boot");
    let client = cluster.client();

    let gen = Generator::new(WorkloadSpec {
        object_size: 256 << 10,
        unit: 8192,
        dedup_pct: 30,
        pool_blocks: 32,
        ..Default::default()
    });
    for i in 0..48 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).expect("put");
    }
    cluster.flush_consistency().ok();

    let before = cluster.stats();
    let per_before: Vec<u64> = before.per_server.iter().map(|s| s.bytes_stored).collect();
    let total_before: u64 = per_before.iter().sum();

    // grow the cluster: epoch bump + cluster-wide rebalance
    let new_id = cluster.add_server().expect("add server");
    println!("[{label}] added {new_id}, epoch now {}", cluster.epoch());

    let after = cluster.stats();
    let per_after: Vec<u64> = after.per_server.iter().map(|s| s.bytes_stored).collect();
    let moved_to_new = *per_after.last().unwrap_or(&0);
    let frac = moved_to_new as f64 / total_before.max(1) as f64;
    println!("[{label}] bytes/server before: {per_before:?}");
    println!("[{label}] bytes/server after:  {per_after:?}");
    println!(
        "[{label}] new server took {:.1}% of data (ideal ≈ {:.1}%)",
        frac * 100.0,
        100.0 / 5.0
    );

    // every object still readable, audit still balanced — and crucially no
    // dedup-metadata refresh was ever sent (placement is content-derived).
    for i in 0..48 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).expect("get"), data, "{name} unreadable");
    }
    let audit = cluster.audit().expect("audit");
    assert!(audit.is_ok(), "[{label}] audit violations: {:?}", audit.violations);
    assert!(
        frac > 0.05 && frac < 0.45,
        "[{label}] movement {frac} far from ideal 0.2"
    );
    println!("[{label}] all 48 objects readable after rebalance; audit OK\n");
    cluster.shutdown();
}

fn main() {
    println!("== rebalancing: add a 5th server to a 4-server cluster ==");
    run(Placement::Straw2, "straw2");
    run(Placement::Rendezvous, "rendezvous");
    println!("rebalancing OK");
}
