//! Quickstart: boot a cluster, write duplicated objects, read them back,
//! inspect savings — with both fingerprint engines (scalar Rust SHA-1 and
//! the AOT Pallas kernel through PJRT when `artifacts/` is present).
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use snss_dedup::api::{Cluster, ClusterConfig, DedupMode, FingerprintBackend};
use snss_dedup::dedup::Chunking;
use snss_dedup::workload::{Generator, WorkloadSpec};
use std::time::Instant;

fn run(label: &str, fingerprint: FingerprintBackend) {
    let cluster = Cluster::new(ClusterConfig {
        servers: 4,
        replication: 2,
        dedup: DedupMode::ClusterWide,
        chunking: Chunking::Fixed { size: 4096 },
        fingerprint,
        ..Default::default()
    })
    .expect("boot cluster");
    let client = cluster.client();

    // a 25%-duplicate workload of 16 x 1 MiB objects
    let gen = Generator::new(WorkloadSpec {
        object_size: 1 << 20,
        unit: 4096,
        dedup_pct: 25,
        pool_blocks: 64,
        ..Default::default()
    });

    let t0 = Instant::now();
    for i in 0..16 {
        let (name, data) = gen.named_object(i);
        client.put_object(&name, &data).expect("put");
    }
    let write_dt = t0.elapsed();

    // read everything back and verify
    for i in 0..16 {
        let (name, data) = gen.named_object(i);
        assert_eq!(client.get_object(&name).expect("get"), data, "readback {name}");
    }

    cluster.flush_consistency().ok();
    let stats = cluster.stats();
    let audit = cluster.audit().expect("audit");
    println!(
        "[{label:<10}] wrote {} MiB in {:>6.1} ms ({:>7.1} MiB/s) | savings {:>4.1}% | \
         dedup hits {:>4} | audit {}",
        stats.logical_bytes >> 20,
        write_dt.as_secs_f64() * 1e3,
        (stats.logical_bytes as f64 / (1 << 20) as f64) / write_dt.as_secs_f64(),
        stats.savings() * 100.0,
        stats.dedup_hits,
        if audit.is_ok() { "OK" } else { "VIOLATIONS" }
    );
    assert!(audit.is_ok(), "{:?}", audit.violations);
    cluster.shutdown();
}

fn main() {
    println!("== quickstart: 4-server cluster-wide dedup ==");
    run("rust-sha1", FingerprintBackend::RustSha1);
    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        run(
            "xla-pallas",
            FingerprintBackend::Xla {
                artifacts_dir: "artifacts".into(),
            },
        );
    } else {
        println!("[xla-pallas] skipped: run `make artifacts` first");
    }
    println!("quickstart OK");
}
